"""Disaggregated serving tests: router decision logic under worker
imbalance, fp/frozen page-migration round-trips vs the colocated engine,
engine-level sampling determinism, and the freeze-dispatch budget."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import get_reduced_config
from repro.serving import (ContinuousBatchingEngine, DisaggEngine,
                           DisaggRouter, Request, extract_pages,
                           init_paged_cache, sample_token, splice_payload)
from repro.serving.kv_cache import resolve_kv_spec
from repro.serving.transfer import collect_leaves

pytestmark = pytest.mark.serving


# ------------------------------------------------------------- router


class _FakePrefill:
    def __init__(self, wid, load=0, cap=4):
        self.worker_id, self.load, self.cap = wid, load, cap
        self.got = []

    def can_accept(self):
        return self.load < self.cap

    def submit(self, req):
        self.got.append(req.id)
        self.load += 1


class _FakeDecode:
    def __init__(self, wid, free_slots=1, free_blocks=8, block_size=4):
        self.worker_id, self.free_slots = wid, free_slots
        self.free_blocks, self.block_size = free_blocks, block_size
        self.got = []

    def can_accept(self, req):
        need = -(-(req.prompt_len + req.max_new_tokens) // self.block_size)
        return self.free_slots > 0 and need <= self.free_blocks

    def place(self, fin):
        self.got.append(fin.req.id)
        self.free_slots -= 1
        self.free_blocks -= -(-(fin.req.prompt_len
                                + fin.req.max_new_tokens) // self.block_size)


def _req(i, plen=4, gen=4):
    return Request(id=i, prompt=(1,) * plen, max_new_tokens=gen)


class _FakeFin:
    def __init__(self, req):
        self.req = req


def test_router_prefill_least_loaded_under_imbalance():
    """Requests drain to the least-loaded prefill worker; a saturated
    worker is skipped entirely; ties break on worker id (deterministic)."""
    router = DisaggRouter()
    a, b, c = _FakePrefill(0, load=3), _FakePrefill(1, load=0), \
        _FakePrefill(2, load=0, cap=0)          # c: saturated from the start
    for i in range(5):
        assert router.submit(_req(i))
    router.route_prefill([a, b, c])
    assert c.got == []
    # b starts 3 lighter: takes the first three; then a and b alternate
    assert b.got == [0, 1, 2, 4] and a.got == [3]
    assert not router.waiting


def test_router_queue_admission_control():
    router = DisaggRouter(max_queue=2)
    assert router.submit(_req(0)) and router.submit(_req(1))
    assert not router.submit(_req(2))
    assert router.rejected == [2]


def test_router_decode_reevaluates_capacity_per_placement():
    """Two staged prefills must not both be routed against capacity the
    first is about to consume (regression: stale-capacity double-place)."""
    router = DisaggRouter()
    dw = _FakeDecode(0, free_slots=1, free_blocks=8)
    for i in range(2):
        router.stage(_FakeFin(_req(i)))
    placed = router.route_decode([dw], lambda w, fin: w.place(fin))
    assert [f.req.id for _, f in placed] == [0]
    assert dw.got == [0] and len(router.staged) == 1     # second one waits
    dw.free_slots = 1
    placed = router.route_decode([dw], lambda w, fin: w.place(fin))
    assert dw.got == [0, 1] and not router.staged


def test_router_decode_most_free_slots_and_hol_wait():
    """Placement prefers the emptiest decode worker; a head that fits
    nowhere blocks the queue (FCFS, no starvation)."""
    router = DisaggRouter()
    small = _FakeDecode(0, free_slots=2, free_blocks=2)   # big req never fits
    big = _FakeDecode(1, free_slots=1, free_blocks=64)
    router.stage(_FakeFin(_req(0, plen=32, gen=32)))      # needs 16 blocks
    router.stage(_FakeFin(_req(1)))                       # only fits `small`
    # head can't fit `small`: nothing places until it lands on `big`; then
    # the second head places on `small` (the only worker that fits it) in
    # the same sweep — FCFS order preserved, per-placement live capacity
    placed = router.route_decode([small, big],
                                 lambda w, fin: w.place(fin))
    assert [(w.worker_id, f.req.id) for w, f in placed] == [(1, 0), (0, 1)]
    assert not router.staged
    # a head that fits nowhere blocks the queue (FCFS, no starvation)
    router.stage(_FakeFin(_req(2, plen=32, gen=32)))
    router.stage(_FakeFin(_req(3)))
    assert router.route_decode([small, big],
                               lambda w, fin: w.place(fin)) == []
    assert len(router.staged) == 2


def test_staging_depth_backpressures_prefill():
    """With a staging depth, a decode-capacity stall stops route_prefill
    from feeding the prefill workers once in-flight prefills (worker load
    + staged artifacts) hit the limit; freeing decode capacity drains the
    staged queue and reopens prefill intake. Without the limit the staged
    queue grows unboundedly (the pre-limit behavior, kept as default)."""
    router = DisaggRouter(staging_depth=2)
    pw = _FakePrefill(0)
    dec = _FakeDecode(0, free_slots=0)              # decode stalled
    for i in range(6):
        assert router.submit(_req(i))
    assert len(router.route_prefill([pw])) == 2     # capped at depth
    assert pw.load == 2 and len(router.waiting) == 4
    # prefills finish -> staged; decode still stalled, nothing places
    for i in range(2):
        router.stage(_FakeFin(_req(i)))
        pw.load -= 1
    assert router.route_decode([dec], lambda w, f: w.place(f)) == []
    # in-flight (staged) still at depth: prefill intake stays closed
    assert router.route_prefill([pw]) == []
    assert pw.load == 0 and len(router.waiting) == 4
    # decode frees -> staged drains -> intake reopens
    dec.free_slots = 2
    assert len(router.route_decode([dec], lambda w, f: w.place(f))) == 2
    assert len(router.route_prefill([pw])) == 2
    # unbounded default: everything flows to the workers immediately
    router2 = DisaggRouter()
    pw2 = _FakePrefill(0, cap=64)
    for i in range(6):
        router2.submit(_req(i))
    assert len(router2.route_prefill([pw2])) == 6


def test_staging_depth_engine_bounds_inflight(qwen_reduced):
    """End to end: a DisaggEngine with staging_depth=1 never holds more
    than one prefill in flight past the waiting queue, yet completes the
    whole trace (backpressure, not starvation)."""
    cfg, params = qwen_reduced
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, cfg.vocab, 8).tolist() for _ in range(4)]
    eng = DisaggEngine(params, cfg, prefill_workers=1, decode_workers=1,
                       max_slots=1, block_size=8, max_seq_len=16,
                       staging_depth=1)
    out = eng.generate(prompts, max_new_tokens=4)
    assert all(out[i] is not None and len(out[i]) == 4 for i in range(4))
    # queue_peak counts load at submit time: depth 1 means the worker
    # never saw a second prompt queued behind an in-flight one
    assert eng.prefills[0].counters["queue_peak"] <= 1


# ------------------------------------------------------------- sampling


def test_sample_token_greedy_and_determinism():
    row = np.asarray([0.1, 3.0, -1.0, 2.9])
    assert sample_token(row) == 1                       # temperature 0
    assert sample_token(row, temperature=0.0,
                        rng=np.random.default_rng(0)) == 1
    draws1 = [sample_token(row, temperature=1.0, top_k=0,
                           rng=np.random.default_rng(7)) for _ in range(8)]
    draws2 = [sample_token(row, temperature=1.0, top_k=0,
                           rng=np.random.default_rng(7)) for _ in range(8)]
    assert draws1 == draws2                             # per-seed replay
    # top_k=1 collapses to argmax whatever the temperature
    assert all(sample_token(row, temperature=5.0, top_k=1,
                            rng=np.random.default_rng(i)) == 1
               for i in range(5))
    # never samples outside the top-k support
    assert all(sample_token(row, temperature=2.0, top_k=2,
                            rng=np.random.default_rng(i)) in (1, 3)
               for i in range(20))


# ------------------------------------------------------------- model fixtures


@pytest.fixture(scope="module")
def qwen_reduced():
    cfg = get_reduced_config("qwen3_0_6b")
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _mini_cfg():
    return get_reduced_config("qwen3_0_6b")


# ------------------------------------------------------------- transfer


def test_transfer_roundtrip_fp_and_frozen():
    """extract -> to_host -> splice lands the same page content in a fresh
    pool: fp pages bit-exact, frozen pages as the codebook reconstruction
    with blk_q set and codes identical to an in-place freeze."""
    from repro.serving import freeze_blocks

    cfg = _mini_cfg()
    bs, P = 8, 20                                  # 2 full pages + 4 rows
    spec = resolve_kv_spec("kmeans_ls@16")
    kw = dict(num_blocks=8, block_size=bs, batch=1, max_blocks=4,
              quantized=True, num_values=16)
    src = init_paged_cache(cfg, **kw)
    rng = np.random.default_rng(0)
    src = jax.tree_util.tree_map(
        lambda l: dataclasses.replace(
            l, k_fp=jnp.asarray(rng.normal(size=l.k_fp.shape), jnp.float32),
            v_fp=jnp.asarray(rng.normal(size=l.v_fp.shape), jnp.float32)),
        src, is_leaf=lambda x: hasattr(x, "k_fp"))
    blocks, new_blocks = [3, 1, 4], [2, 5, 6]

    for mode in ("fp", "frozen"):
        payload = extract_pages(src, blocks, P, block_size=bs, mode=mode,
                                spec=spec).to_host()
        assert payload.n_full == 2 and payload.tail_rows == 4
        assert payload.nbytes > 0
        if mode == "fp":
            assert payload.nbytes == payload.fp_equiv_bytes
        else:
            # the partial tail page crosses fp in both modes, so compare
            # the full-page portion: codes+codebooks >= 5x under fp rows
            tail_fp = sum(a.nbytes for a in payload.tail)
            assert (payload.nbytes - tail_fp) * 5 < (payload.fp_equiv_bytes
                                                     - tail_fp)
        dst = splice_payload(init_paged_cache(cfg, **kw), payload,
                             new_blocks)
        for sl, dl in zip(collect_leaves(src), collect_leaves(dst)):
            s_k, d_k = np.asarray(sl.k_fp), np.asarray(dl.k_fp)
            ax = 1 if s_k.ndim == 5 else 0
            take = lambda a, ids: np.take(a, ids, axis=ax)
            if mode == "fp":
                np.testing.assert_array_equal(take(d_k, new_blocks[:2]),
                                              take(s_k, blocks[:2]))
                assert not np.asarray(dl.blk_q)[..., new_blocks[:2]].any()
            else:
                # frozen pages land as cb[codes], identical to freezing the
                # same pages in place on the source pool
                ref = freeze_blocks(sl, blocks[:2], spec)
                np.testing.assert_allclose(take(d_k, new_blocks[:2]),
                                           take(np.asarray(ref.k_fp),
                                                blocks[:2]), rtol=1e-6)
                np.testing.assert_array_equal(
                    take(np.asarray(dl.k_codes), new_blocks[:2]),
                    take(np.asarray(ref.k_codes), blocks[:2]))
                assert np.asarray(dl.blk_q)[..., new_blocks[:2]].all()
            # the partial tail page crosses fp in both modes (valid rows)
            np.testing.assert_array_equal(
                take(d_k, [new_blocks[2]])[..., 0, :4, :, :],
                take(s_k, [blocks[2]])[..., 0, :4, :, :])


# ------------------------------------------------------------- engines


def test_smoke_colocated_vs_disagg_fp(qwen_reduced):
    """CI smoke gate: the disaggregated composition reproduces the
    colocated engine exactly on an fp cache (tokens and logits), including
    a non-block-aligned prompt (partial-page migration)."""
    cfg, params = qwen_reduced
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, p).tolist() for p in (12, 8)]
    gen = 5
    kw = dict(max_slots=2, block_size=8, max_seq_len=32, record_logits=True)
    co = ContinuousBatchingEngine(params, cfg, **kw)
    out_co = co.generate(prompts, max_new_tokens=gen)
    dz = DisaggEngine(params, cfg, prefill_workers=1, decode_workers=1,
                      migrate="fp", **kw)
    out_dz = dz.generate(prompts, max_new_tokens=gen)
    assert out_co == out_dz
    for i in range(len(prompts)):
        np.testing.assert_allclose(dz.request_logits[i],
                                   co.request_logits[i], atol=1e-4, rtol=0)
    s = dz.metrics.summary()
    assert s["completed"] == len(prompts)
    c = dz.decode[0].counters
    assert c["migrated_seqs"] == len(prompts)
    assert c["migrate_bytes"] == c["migrate_fp_equiv_bytes"] > 0
    # all pools drained
    assert dz.decode[0].alloc.num_free == dz.decode[0].num_blocks - 1
    assert dz.prefills[0].alloc.num_free == dz.prefills[0].num_blocks - 1


def test_frozen_migration_matches_colocated_sync_freeze(qwen_reduced):
    """migrate="frozen" (pages cross as codes+codebooks through the
    dispatch_freeze path) reproduces the colocated engine with synchronous
    freezing: the solver sees identical page content, so tokens and logits
    match. Budget covers the whole prompt so both freeze pre-decode."""
    cfg, params = qwen_reduced
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab, 32).tolist() for _ in range(2)]
    gen = 6
    kw = dict(max_slots=2, block_size=8, max_seq_len=64,
              kv_quant="kmeans_ls@16", record_logits=True,
              freeze_async=False, freeze_page_budget=64)
    co = ContinuousBatchingEngine(params, cfg, **kw)
    out_co = co.generate(prompts, max_new_tokens=gen)
    dz = DisaggEngine(params, cfg, prefill_workers=1, decode_workers=1,
                      migrate="frozen", **kw)
    out_dz = dz.generate(prompts, max_new_tokens=gen)
    assert out_co == out_dz
    for i in range(len(prompts)):
        np.testing.assert_allclose(dz.request_logits[i],
                                   co.request_logits[i], atol=1e-4, rtol=0)
    c = dz.decode[0].counters
    assert c["host_page_solves"] == 0
    assert c["migrated_pages"] == 2 * (32 // 8)
    # codes+codebooks cross >= 5x cheaper than the fp rows would
    assert c["migrate_fp_equiv_bytes"] >= 5 * c["migrate_bytes"] > 0


def test_disagg_fused_interpret_matches_gather(qwen_reduced):
    """Frozen-migrated pages land directly servable by the fused decode
    kernel: the interpret-mode fused disagg engine reproduces the gather
    disagg engine."""
    cfg, params = qwen_reduced
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab, 10).tolist() for _ in range(2)]
    runs = {}
    for impl in ("gather", "fused"):
        eng = DisaggEngine(params, cfg, prefill_workers=1, decode_workers=1,
                           migrate="frozen", max_slots=2, block_size=8,
                           max_seq_len=32, kv_quant="kmeans_ls@16",
                           record_logits=True, attn_impl=impl,
                           freeze_async=False)
        runs[impl] = (eng, eng.generate(prompts, max_new_tokens=4))
    (g_eng, g_out), (f_eng, f_out) = runs["gather"], runs["fused"]
    assert g_out == f_out
    for i in range(len(prompts)):
        np.testing.assert_allclose(f_eng.request_logits[i],
                                   g_eng.request_logits[i], atol=1e-3,
                                   rtol=0)


def test_disagg_worker_ratio_and_multi_decode(qwen_reduced):
    """2 prefill + 2 decode workers: every request completes, sequences
    spread over both decode workers, and outputs match the colocated
    engine (fp migration is exact)."""
    cfg, params = qwen_reduced
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab, 8).tolist() for _ in range(4)]
    gen = 4
    co = ContinuousBatchingEngine(params, cfg, max_slots=4, block_size=8,
                                  max_seq_len=16)
    out_co = co.generate(prompts, max_new_tokens=gen)
    dz = DisaggEngine(params, cfg, prefill_workers=2, decode_workers=2,
                      migrate="fp", max_slots=2, block_size=8,
                      max_seq_len=16)
    out_dz = dz.generate(prompts, max_new_tokens=gen)
    assert out_dz == out_co
    assert sum(p.counters["prefills"] for p in dz.prefills) == 4
    assert all(d.counters["migrated_seqs"] > 0 for d in dz.decode)


def test_engine_sampling_determinism_per_seed(qwen_reduced):
    """Sampling replays token-identically per seed, differs across seeds,
    and temperature=0 stays exactly the greedy verification path."""
    cfg, params = qwen_reduced
    rng = np.random.default_rng(6)
    prompts = [rng.integers(0, cfg.vocab, 8).tolist() for _ in range(3)]
    gen = 8

    # temperature well above 1: a random-init model's logits are peaked
    # enough that mild temperatures still argmax every step, which would
    # make "different seeds diverge" vacuous
    def run(seed, temperature=5.0, top_k=16):
        eng = ContinuousBatchingEngine(params, cfg, max_slots=2,
                                       block_size=8, max_seq_len=32)
        return eng.generate(prompts, max_new_tokens=gen,
                            temperature=temperature, top_k=top_k, seed=seed)

    a, b, c = run(5), run(5), run(6)
    assert a == b, "same seed must replay token-identically"
    assert a != c, "different seeds should diverge somewhere"
    greedy_default = run(0, temperature=0.0, top_k=0)
    eng = ContinuousBatchingEngine(params, cfg, max_slots=2, block_size=8,
                                   max_seq_len=32)
    assert eng.generate(prompts, max_new_tokens=gen) == greedy_default


def test_freeze_page_budget_defers_burst(qwen_reduced):
    """A prompt burst queuing more full pages than the per-step budget
    defers the remainder to later iterations (counted), and every queued
    page still eventually freezes (installs == dispatches, run drains)."""
    cfg, params = qwen_reduced
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab, 32).tolist() for _ in range(2)]
    eng = ContinuousBatchingEngine(params, cfg, max_slots=2, block_size=8,
                                   max_seq_len=48, kv_quant="kmeans_ls@16",
                                   freeze_page_budget=2)
    eng.generate(prompts, max_new_tokens=8)       # 8 full prompt pages at once
    c = eng.counters
    assert c["freeze_deferred_pages"] > 0, "budget valve never engaged"
    assert c["freeze_installs"] == c["freeze_dispatches"] > 0
    assert not eng._pending_freezes
    # the same burst with an uncapped budget defers nothing
    eng2 = ContinuousBatchingEngine(params, cfg, max_slots=2, block_size=8,
                                    max_seq_len=48, kv_quant="kmeans_ls@16",
                                    freeze_page_budget=64)
    eng2.generate(prompts, max_new_tokens=8)
    assert eng2.counters["freeze_deferred_pages"] == 0


def test_disagg_async_freeze_outliving_sequences_drains(qwen_reduced):
    """Regression: an async freeze dispatched right before its sequence
    finishes must still land — the run loop keys on pending solves, and a
    worker with no live sequences has no decode step to piggyback the
    install poll on (this used to spin forever)."""
    cfg, params = qwen_reduced
    rng = np.random.default_rng(9)
    eng = DisaggEngine(params, cfg, prefill_workers=1, decode_workers=1,
                       migrate="fp", max_slots=2, block_size=8,
                       max_seq_len=32, kv_quant="kmeans_ls@16")
    assert eng.freeze_async
    out = eng.generate([rng.integers(0, cfg.vocab, 16).tolist()],
                       max_new_tokens=2)
    assert len(out[0]) == 2
    dw = eng.decode[0]
    assert not dw._pending_freezes and not dw._freeze_bids
    assert (dw.counters["freeze_installs"]
            == dw.counters["freeze_dispatches"] > 0)


def test_ttft_split_components(qwen_reduced):
    """queue_wait + prefill_compute == TTFT per request, on both engine
    compositions."""
    cfg, params = qwen_reduced
    rng = np.random.default_rng(8)
    prompts = [rng.integers(0, cfg.vocab, 8).tolist() for _ in range(2)]
    for eng in (ContinuousBatchingEngine(params, cfg, max_slots=2,
                                         block_size=8, max_seq_len=16),
                DisaggEngine(params, cfg, prefill_workers=1,
                             decode_workers=1, max_slots=2, block_size=8,
                             max_seq_len=16)):
        eng.generate(prompts, max_new_tokens=4)
        s = eng.metrics.summary()
        assert s["queue_wait_mean_s"] >= 0
        assert s["prefill_compute_mean_s"] > 0
        for tr in eng.metrics.traces.values():
            assert tr.queue_wait + tr.prefill_compute == pytest.approx(
                tr.ttft, abs=1e-9)


def test_disagg_rejects_oversized_and_validates_migrate(qwen_reduced):
    cfg, params = qwen_reduced
    eng = DisaggEngine(params, cfg, prefill_workers=1, decode_workers=1,
                       max_slots=1, block_size=8, max_seq_len=16)
    assert not eng.submit(Request(id=7, prompt=(1,) * 12, max_new_tokens=8),
                          0.0)
    assert 7 in eng.router.rejected
    with pytest.raises(ValueError, match="kv_quant"):
        DisaggEngine(params, cfg, migrate="frozen")
    with pytest.raises(ValueError, match="device"):
        DisaggEngine(params, cfg, migrate="frozen", kv_quant="dtc@16")
    with pytest.raises(ValueError, match="migrate"):
        DisaggEngine(params, cfg, migrate="codes")
