"""Pallas kernel tests: shape/dtype sweeps against pure-jnp oracles
(interpret mode on CPU), plus solver-quality checks vs coordinate descent."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import cd_solve, make_problem, objective, unique_with_counts
from repro.kernels import (
    fista_quant, quant_matmul, ref_fista, ref_quant_matmul, solve_fista_batch,
    power_iter_lipschitz,
)


# ------------------------------------------------------------ quant_matmul

@pytest.mark.parametrize("M,K,N", [(8, 32, 16), (16, 128, 128), (128, 256, 64),
                                   (5, 33, 17)])  # last one exercises padding
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quant_matmul_matches_ref(M, K, N, dtype):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(M, K)), dtype)
    idx = jnp.asarray(rng.integers(0, 16, (K, N)), jnp.uint8)
    cb = jnp.asarray(rng.normal(size=(16,)), jnp.float32)
    out = quant_matmul(x, idx, cb, bm=8, bn=16, bk=32, interpret=True)
    ref = ref_quant_matmul(x, idx, cb)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2  # blocked-k accumulation order
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_quant_matmul_int32_codes_large_codebook():
    rng = np.random.default_rng(1)
    C = 1000
    x = jnp.asarray(rng.normal(size=(16, 64)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, C, (64, 32)), jnp.int32)
    cb = jnp.asarray(rng.normal(size=(C,)), jnp.float32)
    out = quant_matmul(x, idx, cb, bm=8, bn=16, bk=32, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_quant_matmul(x, idx, cb)),
                               atol=1e-4, rtol=1e-4)


# ------------------------------------------------------------ fista_quant

@pytest.mark.parametrize("B,M,T", [(1, 128, 128), (3, 256, 128), (2, 100, 128),
                                   (4, 64, 64)])
def test_fista_kernel_matches_ref(B, M, T):
    """Kernel iterates == pure-jnp FISTA iterates (same math, blocked scans)."""
    rng = np.random.default_rng(2)
    w = np.sort(rng.normal(size=(B, M)), axis=1).astype(np.float32)
    d = np.diff(w, axis=1, prepend=0.0).astype(np.float32)
    n = np.ones((B, M), np.float32)
    lam = np.full((B, M), 0.05, np.float32)
    eta = (1.0 / (power_iter_lipschitz(d, n) * 1.01)).astype(np.float32)

    padM = (-M) % T
    pad = lambda a: np.pad(a, ((0, 0), (0, padM)))
    nb = (M + padM) // T
    a_kern = fista_quant(
        jnp.asarray(pad(w).reshape(B, nb, T)), jnp.asarray(pad(d).reshape(B, nb, T)),
        jnp.asarray(pad(n).reshape(B, nb, T)), jnp.asarray(pad(lam).reshape(B, nb, T)),
        jnp.asarray(eta.reshape(B, 1, 1)), n_iters=50, block_t=T, interpret=True,
    )
    a_kern = np.asarray(a_kern).reshape(B, -1)[:, :M]
    a_ref = np.asarray(ref_fista(jnp.asarray(w), jnp.asarray(d), jnp.asarray(n),
                                 jnp.asarray(lam), jnp.asarray(eta), n_iters=50))
    np.testing.assert_allclose(a_kern, a_ref, atol=2e-4, rtol=1e-3)


def test_fista_converges_to_cd_objective():
    """Solver quality: FISTA reaches the CD (global) objective within 1%."""
    rng = np.random.default_rng(3)
    w = rng.normal(0, 1, 500).round(2)
    vals, counts, _ = unique_with_counts(w)
    prob = make_problem(vals, counts)
    m = prob.m
    d = np.asarray(prob.d)[None, :]
    wv = np.asarray(prob.w_hat)[None, :]
    n = np.ones((1, m), np.float32)
    lam = 0.05
    alpha = solve_fista_batch(wv, d, n, lam, n_iters=2000, interpret=True)
    a_cd, _ = cd_solve(prob, lam, max_sweeps=500, tol=1e-9)
    f_fista = float(objective(prob, jnp.asarray(alpha[0]), lam))
    f_cd = float(objective(prob, a_cd, lam))
    assert f_fista <= f_cd * 1.01 + 1e-4


def test_fista_batch_padding_mask():
    """Zero-weight padded tail must not leak into real coordinates."""
    rng = np.random.default_rng(4)
    m1, m2 = 60, 90
    rows_w = np.zeros((2, m2), np.float32)
    rows_d = np.zeros((2, m2), np.float32)
    rows_n = np.zeros((2, m2), np.float32)
    for i, m in enumerate((m1, m2)):
        v = np.sort(rng.normal(size=m)).astype(np.float32)
        rows_w[i, :m] = v
        rows_d[i, :m] = np.diff(v, prepend=0.0)
        rows_n[i, :m] = 1.0
    a2 = solve_fista_batch(rows_w, rows_d, rows_n, 0.05, n_iters=200, interpret=True)
    # row 0 solved alone must equal row 0 solved in the batch
    a1 = solve_fista_batch(rows_w[:1, :m1], rows_d[:1, :m1], rows_n[:1, :m1],
                           0.05, n_iters=200, interpret=True)
    np.testing.assert_allclose(a2[0, :m1], a1[0], atol=1e-4)
    assert np.all(a2[:, m2:] == 0) if a2.shape[1] > m2 else True
    assert np.all(a2[0, m1:] == 0)


# ------------------------------------------------------------ paged decode


def _paged_state(rng, *, nb, bs, Hkv, Dh, L, quantized, packed, frozen_ids=()):
    from repro.kernels import pack4

    kfp = jnp.asarray(rng.normal(size=(nb, bs, Hkv, Dh)), jnp.float32)
    vfp = jnp.asarray(rng.normal(size=(nb, bs, Hkv, Dh)), jnp.float32)
    if quantized:
        Dc = Dh // 2 if packed else Dh
        kcodes = rng.integers(0, L, (nb, bs, Hkv, Dh)).astype(np.uint8)
        vcodes = rng.integers(0, L, (nb, bs, Hkv, Dh)).astype(np.uint8)
        if packed:
            kcodes, vcodes = (np.asarray(pack4(jnp.asarray(c)))
                              for c in (kcodes, vcodes))
        kc, vc = jnp.asarray(kcodes), jnp.asarray(vcodes)
        kcb = jnp.asarray(rng.normal(size=(nb, L)), jnp.float32)
        vcb = jnp.asarray(rng.normal(size=(nb, L)), jnp.float32)
        blkq = np.zeros((nb,), np.int32)
        blkq[list(frozen_ids)] = 1
        blkq = jnp.asarray(blkq)
    else:
        kc = vc = jnp.zeros((1, 1, 1, 1), jnp.uint8)
        kcb = vcb = jnp.zeros((1, 1), jnp.float32)
        blkq = jnp.zeros((1,), jnp.int32)
    return kfp, vfp, kc, vc, kcb, vcb, blkq


@pytest.mark.parametrize("quantized,packed,softcap", [
    (True, True, None), (True, False, None), (False, True, None),
    (True, True, 30.0)])
def test_paged_decode_kernel_matches_oracle(quantized, packed, softcap):
    """Fused flash-decode == dense oracle on mixed frozen/hot pages with
    per-sequence valid lengths (incl. an idle slot parked on the null
    page)."""
    from repro.kernels import paged_decode_attention, ref_paged_decode

    rng = np.random.default_rng(0)
    nb, bs, Hkv, Dh, L, B, mb, Hq = 7, 8, 2, 16, 16, 3, 3, 4
    state = _paged_state(rng, nb=nb, bs=bs, Hkv=Hkv, Dh=Dh, L=L,
                         quantized=quantized, packed=packed,
                         frozen_ids=(1, 4, 5))
    table = jnp.asarray([[1, 2, 3], [4, 5, 6], [0, 0, 0]], jnp.int32)
    valid = jnp.asarray([3 * bs, bs + 3, 1], jnp.int32)   # full / partial / idle
    q = jnp.asarray(rng.normal(size=(B, Hq, Dh)), jnp.float32)
    out = paged_decode_attention(q, *state, table, valid, softcap=softcap,
                                 quantized=quantized, packed=packed,
                                 interpret=True)
    ref = ref_paged_decode(q, *state, table, valid, softcap=softcap,
                           quantized=quantized, packed=packed)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=1e-4)


def test_paged_decode_skips_pages_past_valid():
    """Pages beyond ceil(valid/bs) must not influence the output: poison
    them with huge fp values and check against a table that never maps
    them."""
    from repro.kernels import paged_decode_attention

    rng = np.random.default_rng(1)
    nb, bs, Hkv, Dh, B, mb, Hq = 5, 8, 2, 16, 1, 3, 4
    state = list(_paged_state(rng, nb=nb, bs=bs, Hkv=Hkv, Dh=Dh, L=16,
                              quantized=False, packed=True))
    q = jnp.asarray(rng.normal(size=(B, Hq, Dh)), jnp.float32)
    valid = jnp.asarray([bs + 2], jnp.int32)              # 2 pages needed
    clean = paged_decode_attention(q, *state, jnp.asarray([[1, 2, 3]],
                                   jnp.int32), valid, interpret=True)
    poisoned = [state[0].at[4].set(1e9), state[1].at[4].set(1e9)] + state[2:]
    out = paged_decode_attention(q, *poisoned, jnp.asarray([[1, 2, 4]],
                                 jnp.int32), valid, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(clean), atol=1e-6)


def test_quantize_pages_device_quality():
    """Batched on-device kmeans_ls: codes index a sorted L-wide codebook and
    reconstruction error is small for clusterable rows."""
    from repro.kernels import quantize_pages_device

    rng = np.random.default_rng(2)
    centers = rng.normal(size=(3, 8)) * 5
    rows = (centers[:, rng.integers(0, 8, 256)]
            + rng.normal(size=(3, 256)) * 0.05).astype(np.float32)
    codes, cb = quantize_pages_device(jnp.asarray(rows), num_values=8)
    codes, cb = np.asarray(codes), np.asarray(cb)
    assert codes.shape == (3, 256) and cb.shape == (3, 8)
    assert codes.max() < 8
    assert np.all(np.diff(cb, axis=1) >= 0), "codebooks must be sorted"
    recon = np.take_along_axis(cb, codes.astype(np.int64), axis=1)
    rms = np.sqrt(((recon - rows) ** 2).mean()) / np.sqrt((rows ** 2).mean())
    assert rms < 0.05, rms


def test_quantize_pages_fista_budget_and_quality():
    """Batched FISTA lam-method page solver: per-row lambda bisection lands
    the support inside the count budget, codebooks are sorted and exactly
    L wide, and the full-row LS refit beats a crude 2-level quantizer."""
    from repro.kernels import quantize_pages_device, quantize_pages_fista

    rng = np.random.default_rng(3)
    # mixed difficulty: clusterable rows and raw gaussian rows
    centers = rng.normal(size=(2, 6)) * 4
    clustered = (centers[:, rng.integers(0, 6, 320)]
                 + rng.normal(size=(2, 320)) * 0.05)
    gauss = rng.normal(size=(2, 320))
    rows = jnp.asarray(np.concatenate([clustered, gauss]).astype(np.float32))
    L = 16
    codes, cb = quantize_pages_fista(rows, num_values=L)
    codes, cb = np.asarray(codes), np.asarray(cb)
    assert codes.shape == rows.shape and cb.shape == (4, L)
    assert codes.dtype == np.uint8 and codes.max() < L
    assert np.all(np.diff(cb, axis=1) >= -1e-5), "codebooks must be sorted"
    recon = np.take_along_axis(cb, codes.astype(np.int64), axis=1)
    err = ((recon - np.asarray(rows)) ** 2).mean(axis=1)
    # sanity floor: a 2-level (sign * mean|x|) quantizer per row
    crude = np.sign(np.asarray(rows)) * np.abs(np.asarray(rows)).mean(
        axis=1, keepdims=True)
    crude_err = ((crude - np.asarray(rows)) ** 2).mean(axis=1)
    assert np.all(err < 0.5 * crude_err), (err, crude_err)
    # within striking distance of the exact-DP kmeans_ls backend (the l1
    # path trades a little loss for the lam parameterisation)
    ck, cbk = quantize_pages_device(rows, num_values=L)
    reck = np.take_along_axis(np.asarray(cbk),
                              np.asarray(ck).astype(np.int64), axis=1)
    kerr = ((reck - np.asarray(rows)) ** 2).mean(axis=1)
    assert err.mean() < 5.0 * kerr.mean() + 1e-6, (err.mean(), kerr.mean())
