"""QuantSpec + solver-registry API: string/JSON round-trips, construction-
time rejection of mis-parameterised specs, the legacy-kwargs deprecation
shim, and registry completeness (every registered method quantizes end to
end through the one spec-driven surface; every device entry honors the
(rows, spec) -> (codes, cb) contract)."""
import dataclasses
import warnings

import numpy as np
import pytest

from repro.core import QuantSpec, as_spec, quantize, registry


def _valid_spec(method: str, **kw) -> QuantSpec:
    """A canonical valid spec for any registered method."""
    if registry.get(method).param_kind == "count":
        return QuantSpec(method, num_values=kw.pop("num_values", 10), **kw)
    return QuantSpec(method, lam=kw.pop("lam", 0.05), **kw)


def _data(n=160, seed=0):
    return np.random.default_rng(seed).normal(size=n).astype(np.float32)


# --------------------------------------------------------------- round-trip


@pytest.mark.parametrize("s", [
    "kmeans_ls@16",
    "l1_ls:lam=0.02",
    "l1l2:lam=0.05,lam2=0.01",
    "kmeans_ls@16:weighted=true,seed=3",
    "kmeans@8:clip=-1.0..1.0",
    "iter_l1@16:weighted=true",
    "tv:lam=0.0002",
])
def test_doc_examples_round_trip(s):
    spec = QuantSpec.parse(s)
    assert QuantSpec.parse(str(spec)) == spec
    assert QuantSpec.from_json(spec.to_json()) == spec
    assert as_spec(str(spec)) == spec


def test_parse_is_idempotent_on_spec_objects():
    spec = QuantSpec("kmeans_ls", num_values=16)
    assert QuantSpec.parse(spec) is spec
    assert as_spec(spec) is spec


def _random_spec(rng) -> QuantSpec:
    method = registry.methods()[rng.integers(len(registry.methods()))]
    kw = {}
    if registry.get(method).param_kind == "count":
        kw["num_values"] = int(rng.integers(1, 4096))
    else:
        kw["lam"] = float(10.0 ** rng.uniform(-6, 2))
        if registry.get(method).accepts_lam2 and rng.random() < 0.5:
            kw["lam2"] = float(10.0 ** rng.uniform(-6, 2))
    kw["weighted"] = bool(rng.random() < 0.5)
    kw["seed"] = int(rng.integers(0, 2**31 - 1))
    if rng.random() < 0.5:
        lo = float(rng.normal() * 10)
        kw["clip"] = (lo, lo + float(abs(rng.normal()) + 1e-6))
    return QuantSpec(method, **kw)


def test_round_trip_property_seeded_sweep():
    """parse(str(spec)) == spec and JSON round-trips over a seeded random
    spec corpus — runs everywhere, no hypothesis required."""
    rng = np.random.default_rng(0)
    for _ in range(300):
        spec = _random_spec(rng)
        assert QuantSpec.parse(str(spec)) == spec, spec
        assert QuantSpec.from_json(spec.to_json()) == spec, spec


def test_round_trip_property():
    """Same property, hypothesis-driven when hypothesis is installed."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    floats = st.floats(min_value=1e-6, max_value=1e3, allow_nan=False,
                       allow_infinity=False)

    @st.composite
    def specs(draw):
        method = draw(st.sampled_from(registry.methods()))
        kw = {}
        if registry.get(method).param_kind == "count":
            kw["num_values"] = draw(st.integers(min_value=1, max_value=4096))
        else:
            kw["lam"] = draw(floats)
            if registry.get(method).accepts_lam2:
                kw["lam2"] = draw(st.none() | floats)
        kw["weighted"] = draw(st.booleans())
        kw["seed"] = draw(st.integers(min_value=0, max_value=2**31 - 1))
        lo = draw(st.none() | floats)
        if lo is not None:
            kw["clip"] = (-lo, lo + draw(floats))
        return QuantSpec(method, **kw)

    @hyp.given(specs())
    @hyp.settings(max_examples=200, deadline=None)
    def check(spec):
        assert QuantSpec.parse(str(spec)) == spec
        assert QuantSpec.from_json(spec.to_json()) == spec

    check()


# ---------------------------------------------------------------- rejection


def test_count_budget_rejected_on_lam_methods():
    for m in registry.lam_methods():
        with pytest.raises(ValueError, match="lam-parameterised"):
            QuantSpec(m, lam=0.05, num_values=16)
        with pytest.raises(ValueError, match="lam"):
            QuantSpec.parse(f"{m}@16")         # missing lam is also an error


def test_lam_rejected_on_count_methods():
    for m in registry.count_methods():
        with pytest.raises(ValueError, match="count-parameterised"):
            QuantSpec(m, num_values=16, lam=0.05)
        with pytest.raises(ValueError, match="count-parameterised"):
            QuantSpec.parse(f"{m}:lam=0.05")   # missing budget, stray lam


def test_construction_time_errors():
    with pytest.raises(ValueError, match="unknown quantization method"):
        QuantSpec("nosuch", num_values=16)
    with pytest.raises(ValueError, match="lam2"):
        QuantSpec("l1", lam=0.05, lam2=0.01)   # lam2 is l1l2-only
    with pytest.raises(ValueError, match="num_values must be >= 1"):
        QuantSpec("kmeans_ls", num_values=0)
    with pytest.raises(ValueError, match="bad count budget"):
        QuantSpec.parse("kmeans_ls@lots")
    with pytest.raises(ValueError, match="unknown spec option"):
        QuantSpec.parse("kmeans_ls@16:frobnicate=1")
    with pytest.raises(ValueError, match="clip"):
        QuantSpec.parse("kmeans_ls@16:clip=1.0")


def test_spec_plus_loose_kwargs_is_an_error():
    with pytest.raises(TypeError, match="fold them into the spec"):
        quantize(_data(), "kmeans_ls@16", num_values=8)
    with pytest.raises(TypeError, match="fold them into the spec"):
        quantize(_data(), QuantSpec("kmeans_ls", num_values=16),
                 weighted=True)


# -------------------------------------------------------------- legacy shim


def test_legacy_kwargs_shim_warns_and_matches_spec_path():
    w = _data()
    with pytest.warns(DeprecationWarning, match="deprecated"):
        qt_old, info_old = quantize(w, "kmeans_ls", num_values=8,
                                    weighted=True)
    qt_new, info_new = quantize(w, "kmeans_ls@8:weighted=true")
    np.testing.assert_array_equal(np.asarray(qt_old.to_dense()),
                                  np.asarray(qt_new.to_dense()))
    assert info_old["l2_loss"] == info_new["l2_loss"]
    assert info_new["spec"]["str"] == "kmeans_ls@8:weighted=true"


# ------------------------------------------------------------ hashability


def test_spec_is_hashable_and_usable_as_jit_key():
    a = QuantSpec("kmeans_ls", num_values=16)
    b = QuantSpec.parse("kmeans_ls@16")
    assert a == b and hash(a) == hash(b)
    cache = {a: 1}
    assert cache[b] == 1
    c = dataclasses.replace(a, num_values=8)
    assert c != a and c.num_values == 8
    with pytest.raises(dataclasses.FrozenInstanceError):
        a.num_values = 4


# ---------------------------------------------------- registry completeness


@pytest.mark.parametrize("method", registry.methods())
def test_every_registered_method_quantizes_end_to_end(method):
    """The registry is the single source of truth: each entry must solve
    through the public spec surface. A newly registered method gets this
    end-to-end coverage automatically."""
    w = _data(200, seed=1)
    spec = _valid_spec(method)
    qt, info = quantize(w, spec)
    recon = np.asarray(qt.to_dense())
    assert recon.shape == w.shape
    assert np.isfinite(recon).all()
    assert info["l2_loss"] < float(np.sum(w.astype(np.float64) ** 2))
    assert info["spec"]["method"] == method
    if spec.param_kind == "count":
        assert qt.num_values <= spec.num_values


@pytest.mark.parametrize("method", registry.device_methods())
def test_every_device_entry_honors_the_row_contract(method):
    """(rows, spec) -> (codes u8 (R, E), cb f32 (R, L)) with in-budget
    codes and sorted, exactly-L-wide codebooks."""
    import jax.numpy as jnp

    L = 8
    rows = jnp.asarray(
        np.random.default_rng(2).normal(size=(4, 96)).astype(np.float32))
    spec = QuantSpec(method, num_values=L)
    codes, cb = registry.device_batch_solve(method)(rows, spec)
    codes, cb = np.asarray(codes), np.asarray(cb)
    assert codes.shape == rows.shape and codes.dtype == np.uint8
    assert cb.shape == (4, L) and cb.dtype == np.float32
    assert codes.max() < L
    assert np.all(np.diff(cb, axis=1) >= -1e-5), "codebooks sorted"
    rec = np.take_along_axis(cb, codes.astype(int), axis=1)
    mse = float(((rec - np.asarray(rows)) ** 2).mean())
    assert mse < float(np.asarray(rows).var()), "must beat the 1-value bound"


def test_capability_tuples_derive_from_registry():
    from repro.core import ALL_METHODS, COUNT_METHODS, LAM_METHODS

    assert set(LAM_METHODS) == set(registry.lam_methods())
    assert set(COUNT_METHODS) == set(registry.count_methods())
    assert set(ALL_METHODS) == set(registry.methods())
    assert set(registry.device_methods()) <= set(registry.count_methods())
    # freezing capability is declared, not re-derived, in serving
    from repro.serving import DEVICE_FREEZE_METHODS

    assert tuple(DEVICE_FREEZE_METHODS) == registry.device_methods()


def test_device_solver_resolution_errors_name_capable_methods():
    with pytest.raises(ValueError) as ei:
        registry.device_batch_solve("dtc")
    for m in registry.device_methods():
        assert m in str(ei.value)
